"""Sim-vs-real validation: replay ONE trace through both the live
gateway stack and the discrete-event simulator, and diff the results.

``core/calibrate.py`` closes the loop in one direction (measured costs
flow into the simulator's constants); this harness closes it in the
other: the simulator's *predictions* are checked against the real
``HydraPlatform`` under the identical (thinned) trace. Per-metric
deltas are reported for cold starts, pool claims, p50/p99, memory, and
density. Two gates are enforced:

* **cold starts** — ``|live_cold - sim_cold| <= atol + rtol * sim_cold``
  with ``atol=8``/``rtol=1.0`` by default. Deliberately coarse: live
  timing jitters and the sim packs by per-invocation memory while the
  platform packs by per-function estimate, so exact counts never match —
  but a regression that defeats the warm pool (every request
  cold-booting) blows past any sane tolerance.
* **p99 latency** — ``|live_p99 - sim_p99| <= p99_atol_wall * compress
  + p99_rtol * sim_p99``. Live latencies are recorded in trace seconds
  (wall x compress) while real startup costs do NOT compress with the
  replay clock, so the live p99 carries a compress-amplified startup
  term; the absolute allowance is therefore expressed in *wall* seconds
  (``p99_atol_wall=1.0`` by default) and scaled by ``compress`` so the
  gate means the same thing at any replay speed. A latency regression
  (requests serialized behind a dead pool, a stuck queue) shows up as
  multiple seconds of *wall* divergence and fails at any compression.

**Round trip** (``--round-trip``): the same live replay's
``CalibrationProbe`` payload is turned into a ``hydra-calibration/v1``
overlay (``core.calibrate.calibration_from_replay``), the simulator
re-runs with the measured costs, and the harness asserts the calibrated
sim tracks the live run *at least as closely* as the uncalibrated sim on
cold starts AND p99 — the gateway -> calibration -> sim loop the
simulator's trace-level claims rest on (CI ``roundtrip-smoke``).

For comparability the live side runs with a FIXED pool (autoscaling
off) sized like the sim model's, no SLO timeout, and no tenant
throttling; the sim side gets ``keepalive_s`` stretched past the trace
horizon because a live platform never expires a placed function.

CLI::

    PYTHONPATH=src python -m repro.gateway.validate \\
        --trace-file benchmarks/data/azure_sample.csv \\
        --target-rps 2 --max-minutes 10 --compress 120 --round-trip
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import sys
from typing import Optional

from repro.core.calibrate import apply_calibration, calibration_from_replay
from repro.core.metrics import DEFAULT_RESERVOIR
from repro.core.platform import HydraPlatform, PlatformParams
from repro.core.sim import SimParams, simulate
from repro.core.traces import Trace, discover_azure_tables
from repro.core.tracing import Tracer
from repro.gateway.replay import ReplayConfig, replay_trace

# enforced cold-start gate: |live - sim| <= COLD_ATOL + COLD_RTOL * sim
COLD_ATOL = 8
COLD_RTOL = 1.0

# enforced p99 gate: |live - sim| <= P99_ATOL_WALL_S * compress
#                                     + P99_RTOL * sim_p99
# (atol in WALL seconds: live startup does not compress, so its
# trace-time imprint scales with the compression factor). 1.0 wall
# second absorbs scheduler noise on a busy 2-core CI runner (observed
# live p99 jitter is tenths of a wall second) while a regression that
# defeats the warm pool — requests serialized behind inline boots —
# measures multiple wall seconds and still fails at any compression.
P99_ATOL_WALL_S = 1.0
P99_RTOL = 1.0

# round-trip slack: the calibrated sim must be at least as close to live
# as the uncalibrated one, modulo a little integer jitter on cold counts
ROUNDTRIP_COLD_SLACK = 2

# per-metric deltas reported (summary-schema keys)
DELTA_KEYS = ("requests", "dropped", "cold_runtime", "pool_claims",
              "p50_s", "p99_s", "mean_mem_mb", "ops_per_gb_s")


def load_trace(trace_file: Optional[str] = None,
               target_rps: Optional[float] = None,
               max_minutes: Optional[int] = None,
               seed: int = 0, **synthetic_kw) -> Trace:
    """An Azure-format trace (sibling duration/memory tables
    auto-discovered) when ``trace_file`` is given, else the synthetic
    Shahrad-calibrated generator."""
    if trace_file:
        return Trace.from_azure(trace_file,
                                **discover_azure_tables(trace_file),
                                target_rps=target_rps,
                                max_minutes=max_minutes, seed=seed)
    kw = dict(n_functions=24, n_tenants=8, duration_s=120.0, mean_rps=3.0,
              seed=seed)
    kw.update(synthetic_kw)
    return Trace.synthetic(**kw)


def sim_params_for_live(trace, *, pool_size: int,
                        live_runtime_budget: int, mem_scale: float,
                        base: Optional[SimParams] = None) -> SimParams:
    """Map the live platform's configuration onto ``SimParams`` so the
    two replays model the same deployment: same pool target, the
    per-runtime cap un-scaled back to trace bytes, and keep-alive
    stretched past the horizon (a live platform never expires a placed
    function — only idle arenas TTL out)."""
    base = base or SimParams()
    return dataclasses.replace(
        base,
        pool_size=pool_size,
        runtime_cap=max(base.runtime_cap,
                        int(live_runtime_budget / mem_scale)),
        keepalive_s=max(base.keepalive_s, trace.duration_s + 120.0),
    )


def gate(live: float, sim: float, atol: float, rtol: float) -> dict:
    """One ``|live - sim| <= atol + rtol * sim`` tolerance check."""
    limit = atol + rtol * sim
    delta = abs(live - sim)
    return {"live": live, "sim": sim, "delta": delta,
            "atol": atol, "rtol": rtol, "limit": limit,
            "passed": bool(delta <= limit)}


def round_trip_check(live_summary: dict, sim_summary: dict,
                     cal_summary: dict, *,
                     cold_slack: int = ROUNDTRIP_COLD_SLACK) -> dict:
    """Is the calibrated sim at least as close to live as the
    uncalibrated sim, on cold starts AND p99?

    ``cold_slack`` absorbs integer jitter on cold counts (a calibrated
    refill window can shift one boundary boot either way); p99 closeness
    is required outright — the compress-amplified startup term is
    exactly what calibration exists to capture, so losing ground there
    means the round trip is broken."""
    out = {}
    for key, slack in (("cold_runtime", cold_slack), ("p99_s", 0.0)):
        live, un, cal = (live_summary[key], sim_summary[key],
                         cal_summary[key])
        d_un, d_cal = abs(live - un), abs(live - cal)
        out[key] = {"live": live, "uncalibrated": un, "calibrated": cal,
                    "uncal_delta": d_un, "cal_delta": d_cal,
                    "slack": slack,
                    "passed": bool(d_cal <= d_un + slack)}
    out["passed"] = all(out[k]["passed"] for k in ("cold_runtime", "p99_s"))
    return out


def run_validation(trace, *, compress: float = 60.0, pool_size: int = 4,
                   mem_scale: float = 1.0 / 64,
                   runtime_budget: Optional[int] = None,
                   model: str = "hydra-pool",
                   atol: int = COLD_ATOL, rtol: float = COLD_RTOL,
                   p99_atol_wall: float = P99_ATOL_WALL_S,
                   p99_rtol: float = P99_RTOL,
                   n_workers: int = 8,
                   sim_base: Optional[SimParams] = None,
                   round_trip: bool = False,
                   cold_slack: int = ROUNDTRIP_COLD_SLACK,
                   attribute: bool = False) -> dict:
    """Replay ``trace`` live and simulated; return the delta report.
    With ``round_trip=True``, additionally derive a calibration from the
    live run itself, re-simulate with it, and gate on the calibrated sim
    tracking live at least as tightly as the uncalibrated sim. With
    ``attribute=True``, span-trace every live request and report which
    phase dominates the latency tail and the cold requests — the
    measured explanation behind any live-vs-sim p99/cold delta."""
    base = sim_base or SimParams()
    live_budget = runtime_budget or max(
        4 << 20, int(base.runtime_cap * mem_scale))
    # isolate TTLs are trace-time semantics: compress them with the
    # replay clock, or idle arenas pin runtime budgets for the entire
    # compressed replay and every burst OOMs
    platform = HydraPlatform(PlatformParams(
        pool_size=pool_size, runtime_budget_bytes=live_budget,
        arena_ttl_s=base.isolate_ttl_s / compress, n_workers=4,
        hist_max_samples=DEFAULT_RESERVOIR))
    cfg = ReplayConfig(compress=compress, mem_scale=mem_scale,
                       n_workers=n_workers, autoscale=False,
                       slo_timeout_s=None, tenant_rate=None)
    tracer = Tracer(1.0, seed=0) if attribute else None
    try:
        live, extras = replay_trace(trace, platform, cfg, tracer=tracer)
    finally:
        platform.shutdown()

    params = sim_params_for_live(trace, pool_size=pool_size,
                                 live_runtime_budget=live_budget,
                                 mem_scale=mem_scale, base=base)
    sim = simulate(trace, model, params)

    live_s, sim_s = live.summary(), sim.summary()
    deltas = {}
    for k in DELTA_KEYS:
        lv, sv = live_s.get(k), sim_s.get(k)
        deltas[k] = {"live": lv, "sim": sv,
                     "delta": (lv - sv)
                     if isinstance(lv, (int, float))
                     and isinstance(sv, (int, float)) else None}

    cold = gate(live.cold_runtime_starts, sim.cold_runtime_starts,
                atol, rtol)
    p99 = gate(live_s["p99_s"], sim_s["p99_s"],
               p99_atol_wall * compress, p99_rtol)

    failures = []
    if not live_s["requests"]:
        failures.append("live replay served zero requests")
    if not sim_s["requests"]:
        failures.append("sim replay served zero requests")
    for side, s in (("live", live_s), ("sim", sim_s)):
        for k in ("p50_s", "p99_s", "mean_mem_mb"):
            v = s.get(k)
            if v is None or not math.isfinite(v):
                failures.append(f"{side} {k} is not finite ({v})")
    if not extras.get("drained", True):
        failures.append("gateway did not drain before the timeout")
    err_n = extras.get("drops", {}).get("error", 0)
    if err_n > max(1, 0.01 * len(trace)):
        failures.append(f"{err_n} invoke errors (>1% of the trace): "
                        f"{extras.get('errors', [])[:3]}")
    if not cold["passed"]:
        failures.append(
            f"cold-start divergence {cold['delta']} beyond tolerance "
            f"{cold['limit']:.1f} (live={cold['live']}, sim={cold['sim']}, "
            f"atol={atol}, rtol={rtol})")
    if not p99["passed"]:
        failures.append(
            f"p99 divergence {p99['delta']:.3f}s beyond tolerance "
            f"{p99['limit']:.3f}s (live={p99['live']:.3f}, "
            f"sim={p99['sim']:.3f}, atol={p99_atol_wall:g} wall-s x "
            f"{compress:g}, rtol={p99_rtol:g})")

    report = {
        "trace": trace.describe(),
        "live": live_s, "sim": sim_s, "deltas": deltas,
        "extras": extras,
        # legacy alias for the cold gate (kept so downstream consumers
        # of the report schema keep working)
        "tolerance": {"atol": atol, "rtol": rtol, "limit": cold["limit"],
                      "cold_live": cold["live"], "cold_sim": cold["sim"],
                      "cold_delta": cold["delta"],
                      "passed": cold["passed"]},
        "gates": {"cold_runtime": cold, "p99_s": p99},
    }
    if tracer is not None:
        report["attribution"] = tracer.attribution()

    if round_trip:
        try:
            calibration = calibration_from_replay(live, extras)
        except ValueError as e:
            # a replay that measured nothing (zero requests, everything
            # dropped at the door) must surface as a failure in the
            # report, not a traceback that loses the gate diagnostics
            calibration = None
            failures.append(f"round trip: {e}")
    if round_trip and calibration is not None:
        sim_cal = simulate(trace, model,
                           apply_calibration(params,
                                             calibration["measured"]))
        cal_s = sim_cal.summary()
        rt = round_trip_check(live_s, sim_s, cal_s, cold_slack=cold_slack)
        report["calibration"] = calibration
        report["calibrated_sim"] = cal_s
        report["round_trip"] = rt
        if not rt["cold_runtime"]["passed"]:
            c = rt["cold_runtime"]
            failures.append(
                "round trip: calibrated sim cold starts drifted "
                f"further from live than uncalibrated "
                f"(|{c['live']}-{c['calibrated']}|={c['cal_delta']} vs "
                f"|{c['live']}-{c['uncalibrated']}|={c['uncal_delta']} "
                f"+ slack {c['slack']})")
        if not rt["p99_s"]["passed"]:
            c = rt["p99_s"]
            failures.append(
                "round trip: calibrated sim p99 drifted further from "
                f"live than uncalibrated ({c['cal_delta']:.3f}s vs "
                f"{c['uncal_delta']:.3f}s)")

    report["failures"] = failures
    report["ok"] = not failures
    return report


def format_report(report: dict) -> str:
    def fmt(v):
        if v is None:
            return "-"
        return f"{v:.4f}" if isinstance(v, float) else str(v)

    has_cal = "calibrated_sim" in report
    cal = report.get("calibrated_sim", {})
    header = f"{'metric':>14s} {'live':>12s} {'sim':>12s} {'delta':>12s}"
    if has_cal:
        header += f" {'calibrated':>12s}"
    lines = [header]
    for k, d in report["deltas"].items():
        line = (f"{k:>14s} {fmt(d['live']):>12s} {fmt(d['sim']):>12s} "
                f"{fmt(d['delta']):>12s}")
        if has_cal:
            line += f" {fmt(cal.get(k)):>12s}"
        lines.append(line)
    for name, g in report["gates"].items():
        lines.append(
            f"{name} gate: |{fmt(g['live'])} - {fmt(g['sim'])}| = "
            f"{g['delta']:.4g} <= {g['limit']:.4g} -> "
            f"{'PASS' if g['passed'] else 'FAIL'}")
    if "round_trip" in report:
        rt = report["round_trip"]
        for key in ("cold_runtime", "p99_s"):
            c = rt[key]
            lines.append(
                f"round-trip {key}: calibrated delta {c['cal_delta']:.4g} "
                f"vs uncalibrated {c['uncal_delta']:.4g} "
                f"(slack {c['slack']:g}) -> "
                f"{'PASS' if c['passed'] else 'FAIL'}")
        measured = report["calibration"]["measured"]
        lines.append("calibration: " + ", ".join(
            f"{k}={v:.4g}" for k, v in sorted(measured.items())))
    attr = report.get("attribution")
    if attr:
        for label, key in (("p99 tail", "p99"), ("cold", "cold")):
            g = attr.get(key)
            if not g:
                lines.append(f"attribution {label}: no sampled requests "
                             "in this group")
                continue
            dom = g["dominant"]
            lines.append(
                f"attribution {label}: dominant phase {dom} "
                f"(mean {g['phase_mean_ms'].get(dom, 0.0):.2f}ms wall "
                f"over {g['n']} requests)")
    for f in report["failures"]:
        lines.append(f"FAIL: {f}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Replay one trace through the live gateway stack AND "
                    "the simulator; report per-metric deltas and enforce "
                    "the cold-start + p99 tolerances. --round-trip also "
                    "derives a calibration from the live run and checks "
                    "the calibrated sim tracks live at least as tightly.")
    ap.add_argument("--trace-file", default=None,
                    help="Azure Functions 2019-format invocations CSV "
                         "(default: a small synthetic trace)")
    ap.add_argument("--target-rps", type=float, default=None,
                    help="deterministically thin the trace to this mean rps")
    ap.add_argument("--max-minutes", type=int, default=None,
                    help="replay only the first N trace minutes")
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for synthetic traces and thinning")
    ap.add_argument("--compress", type=float, default=60.0,
                    help="trace seconds replayed per wall second")
    ap.add_argument("--pool", type=int, default=4,
                    help="pre-warmed pool size (live and sim)")
    ap.add_argument("--mem-scale", type=float, default=1.0 / 64,
                    help="trace function memory -> live arena scale")
    ap.add_argument("--model", default="hydra-pool",
                    help="sim model to diff the live replay against")
    ap.add_argument("--workers", type=int, default=8,
                    help="gateway worker threads for the live replay")
    ap.add_argument("--atol", type=int, default=COLD_ATOL,
                    help="cold-start gate absolute allowance (count)")
    ap.add_argument("--rtol", type=float, default=COLD_RTOL,
                    help="cold-start gate relative allowance")
    ap.add_argument("--p99-atol-wall", type=float, default=P99_ATOL_WALL_S,
                    help="p99 gate absolute allowance in WALL seconds "
                         "(scaled by --compress)")
    ap.add_argument("--p99-rtol", type=float, default=P99_RTOL,
                    help="p99 gate relative allowance")
    ap.add_argument("--round-trip", action="store_true",
                    help="derive a calibration from the live replay, "
                         "re-simulate with it, and require the "
                         "calibrated sim to track live at least as "
                         "tightly as the uncalibrated sim")
    ap.add_argument("--attribute", action="store_true",
                    help="span-trace every live request and report the "
                         "phase (queue_wait, pool_claim, register, "
                         "arena_acquire, ...) dominating the latency "
                         "tail and the cold requests")
    ap.add_argument("--emit-calibration", default=None, metavar="PATH",
                    help="with --round-trip: also write the derived "
                         "hydra-calibration/v1 JSON here")
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON")
    args = ap.parse_args(argv)

    if args.emit_calibration and not args.round_trip:
        print("validate: --emit-calibration requires --round-trip",
              file=sys.stderr)
        return 2

    trace = load_trace(args.trace_file, target_rps=args.target_rps,
                       max_minutes=args.max_minutes, seed=args.seed)
    d = trace.describe()
    print(f"[validate] trace: {d['invocations']} invocations, "
          f"{d['functions']} fns, {d['tenants']} tenants over "
          f"{d['duration_s']:.0f}s (compress {args.compress:g}x -> "
          f"~{d['duration_s'] / args.compress:.1f}s wall)")
    report = run_validation(trace, compress=args.compress,
                            pool_size=args.pool, mem_scale=args.mem_scale,
                            model=args.model, n_workers=args.workers,
                            atol=args.atol, rtol=args.rtol,
                            p99_atol_wall=args.p99_atol_wall,
                            p99_rtol=args.p99_rtol,
                            round_trip=args.round_trip,
                            attribute=args.attribute)
    print(format_report(report))
    if args.emit_calibration and "calibration" in report:
        from repro.core.calibrate import write_calibration_doc
        write_calibration_doc(args.emit_calibration, report["calibration"])
        print(f"[validate] wrote calibration {args.emit_calibration}")
    if args.json:
        print(json.dumps(report, indent=1, sort_keys=True, default=str))
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
