"""Open-loop load generation: the trace's arrival process on the wall
clock.

Closed-loop drivers (like the synthetic loop in ``launch/serve.py``)
submit the next request when the previous one finishes, so a slow
platform quietly sees *less* load — exactly the feedback that hides
cold-start pain. The ``LoadGenerator`` is open loop: every invocation
is submitted at its trace timestamp (divided by ``compress``) whether
or not earlier requests completed; queueing, throttling, and SLO
misses then land in the gateway where they belong.

If the generator itself falls behind (the submit path stalled longer
than the gap to the next arrival), the invocation is submitted
immediately but keeps its *intended* schedule time, so the lag is
charged to measured latency rather than silently re-timing the trace;
``LoadResult.late``/``max_lag_s`` report how often that happened.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

# a submit later than this (wall seconds) counts as "late" — small
# scheduler jitter below it is noise, not lag
LATE_SLACK_S = 0.010


@dataclass
class LoadResult:
    submitted: int = 0
    accepted: int = 0
    late: int = 0
    max_lag_s: float = 0.0        # worst wall-clock lag behind schedule
    wall_s: float = 0.0           # generator wall-clock run time


class LoadGenerator:
    def __init__(self, trace, gateway, compress: float = 60.0):
        self.trace = trace
        self.gateway = gateway
        self.compress = compress

    def run(self, t0_wall: Optional[float] = None) -> LoadResult:
        """Replay the whole trace; blocks until the last submit."""
        t0 = time.monotonic() if t0_wall is None else t0_wall
        res = LoadResult()
        for inv in self.trace:
            target = t0 + inv.t / self.compress
            now = time.monotonic()
            if target > now:
                time.sleep(target - now)
            else:
                lag = now - target
                if lag > LATE_SLACK_S:
                    res.late += 1
                    res.max_lag_s = max(res.max_lag_s, lag)
            res.submitted += 1
            if self.gateway.submit(inv, sched_wall=target):
                res.accepted += 1
        res.wall_s = time.monotonic() - t0
        return res
