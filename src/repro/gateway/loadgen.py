"""Open-loop load generation: the trace's arrival process on the wall
clock.

Closed-loop drivers (like the synthetic loop in ``launch/serve.py``)
submit the next request when the previous one finishes, so a slow
platform quietly sees *less* load — exactly the feedback that hides
cold-start pain. The ``LoadGenerator`` is open loop: every invocation
is submitted at its trace timestamp (divided by ``compress``) whether
or not earlier requests completed; queueing, throttling, and SLO
misses then land in the gateway where they belong.

If the generator itself falls behind (the submit path stalled longer
than the gap to the next arrival), the invocation is submitted
immediately but keeps its *intended* schedule time, so the lag is
charged to measured latency rather than silently re-timing the trace;
``LoadResult.late``/``max_lag_s`` report how often that happened.

At high ``--compress`` a single submit loop becomes the bottleneck (one
thread sleeping-and-submitting caps the achievable arrival rate), so
``ShardedLoadGenerator`` partitions the trace by tenant
(``tenant % n_shards``) and replays every shard on its own thread
against the same wall ``t0``: the absolute timeline is preserved, each
tenant's arrivals stay FIFO inside one shard, and the shard union is
exactly the unsharded trace. ``Gateway.submit`` is thread-safe, so the
shards need no coordination beyond the shared clock.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Optional

# a submit later than this (wall seconds) counts as "late" — small
# scheduler jitter below it is noise, not lag
LATE_SLACK_S = 0.010


@dataclass
class LoadResult:
    submitted: int = 0
    accepted: int = 0
    late: int = 0
    max_lag_s: float = 0.0        # worst wall-clock lag behind schedule
    wall_s: float = 0.0           # generator wall-clock run time


class LoadGenerator:
    def __init__(self, trace, gateway, compress: float = 60.0):
        self.trace = trace
        self.gateway = gateway
        self.compress = compress

    def run(self, t0_wall: Optional[float] = None) -> LoadResult:
        """Replay the whole trace; blocks until the last submit."""
        t0 = time.monotonic() if t0_wall is None else t0_wall
        res = LoadResult()
        for inv in self.trace:
            target = t0 + inv.t / self.compress
            now = time.monotonic()
            if target > now:
                time.sleep(target - now)
            else:
                lag = now - target
                if lag > LATE_SLACK_S:
                    res.late += 1
                    res.max_lag_s = max(res.max_lag_s, lag)
            res.submitted += 1
            if self.gateway.submit(inv, sched_wall=target):
                res.accepted += 1
        res.wall_s = time.monotonic() - t0
        return res


def shard_trace(trace, n_shards: int, shard_index: int):
    """The tenant partition ``tenant % n_shards == shard_index`` of
    ``trace``. A trace with native sharding (``StreamingTrace.shard``)
    stays lazy; anything else is filtered into a list. The n partitions
    are disjoint and their union is the whole trace."""
    if n_shards <= 1:
        return trace
    native = getattr(trace, "shard", None)
    if callable(native):
        return native(n_shards, shard_index)
    return [inv for inv in trace if inv.tenant % n_shards == shard_index]


class ShardedLoadGenerator:
    """N per-tenant-shard :class:`LoadGenerator` threads sharing one wall
    ``t0``. ``run`` blocks until every shard finishes and returns the
    merged :class:`LoadResult` (counts summed, lags maxed)."""

    def __init__(self, trace, gateway, compress: float = 60.0,
                 n_shards: int = 2):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.gens = [
            LoadGenerator(shard_trace(trace, n_shards, i), gateway, compress)
            for i in range(n_shards)]

    def run(self, t0_wall: Optional[float] = None) -> LoadResult:
        t0 = time.monotonic() if t0_wall is None else t0_wall
        results: list = [None] * len(self.gens)
        errors: list = []

        def drive(i, gen):
            try:
                results[i] = gen.run(t0)
            except BaseException as e:       # surfaced to the caller below
                errors.append(e)

        threads = [threading.Thread(target=drive, args=(i, g),
                                    name=f"loadgen-shard-{i}", daemon=True)
                   for i, g in enumerate(self.gens)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if errors:
            raise errors[0]
        merged = LoadResult()
        for r in results:
            merged.submitted += r.submitted
            merged.accepted += r.accepted
            merged.late += r.late
            merged.max_lag_s = max(merged.max_lag_s, r.max_lag_s)
            merged.wall_s = max(merged.wall_s, r.wall_s)
        return merged
