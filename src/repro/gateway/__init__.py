"""Live serving gateway: wall-clock trace replay against the real Hydra
stack.

Everything before this package measured the live stack with closed-loop
synthetic drivers and projected trace behaviour through the
discrete-event simulator (``repro.core.sim``). The gateway closes the
gap: it replays a ``Trace`` (synthetic or Azure Functions 2019 CSV)
**open-loop in wall-clock time** — with a compression knob so a trace
minute replays in a second — against a real ``HydraRuntime``,
``HydraPlatform``, or ``HydraCluster``, and reports results in the
simulator's own ``SimResult`` schema so live and simulated replays diff
metric-by-metric (``repro.gateway.validate``).

Pieces (one module each):

  * ``targets``  — adapters normalizing the three live stacks;
  * ``workload`` — trace fids materialized as real registered functions;
  * ``gateway``  — the front door: per-function routing, bounded
    per-tenant queues, token-bucket admission, SLO timeouts, worker
    threads; plus the platform ``Autoscaler``
    (``ArrivalRateEstimator`` -> ``AdaptivePoolPolicy`` ->
    ``resize_pool``);
  * ``loadgen``  — open-loop arrival scheduling on the wall clock;
  * ``recorder`` — live metrics -> ``SimResult``;
  * ``replay``   — ``replay_trace(trace, target, cfg)`` orchestration;
  * ``validate`` — sim-vs-real delta report + the enforced cold-start
    tolerance gate (CI ``gateway-smoke``).

Entry points: ``python -m repro.launch.serve --gateway --trace-file ...
--compress 60`` for a live replay, ``python -m repro.gateway.validate``
for the sim-vs-real diff.
"""
from repro.gateway.gateway import Autoscaler, Gateway, GatewayParams
from repro.gateway.loadgen import LoadGenerator, LoadResult
from repro.gateway.recorder import Recorder
from repro.gateway.replay import ReplayConfig, replay_trace
from repro.gateway.targets import (ClusterTarget, PlatformTarget,
                                   RuntimeTarget, TargetAdapter, wrap_target)
from repro.gateway.validate import (format_report, load_trace,
                                    run_validation, sim_params_for_live)
from repro.gateway.workload import TraceWorkload, scaled_runtime_budget

__all__ = [
    "Gateway", "GatewayParams", "Autoscaler", "LoadGenerator", "LoadResult",
    "Recorder", "ReplayConfig", "replay_trace", "TargetAdapter",
    "RuntimeTarget", "PlatformTarget", "ClusterTarget", "wrap_target",
    "TraceWorkload", "scaled_runtime_budget", "run_validation",
    "format_report", "sim_params_for_live", "load_trace",
]
