"""Live serving gateway: wall-clock trace replay against the real Hydra
stack.

Everything before this package measured the live stack with closed-loop
synthetic drivers and projected trace behaviour through the
discrete-event simulator (``repro.core.sim``). The gateway closes the
gap: it replays a ``Trace`` (synthetic or Azure Functions 2019 CSV)
**open-loop in wall-clock time** — with a compression knob so a trace
minute replays in a second — against a real ``HydraRuntime``,
``HydraPlatform``, or ``HydraCluster``, and reports results in the
simulator's own ``SimResult`` schema so live and simulated replays diff
metric-by-metric (``repro.gateway.validate``).

Pieces (one module each):

  * ``targets``  — adapters normalizing the three live stacks;
  * ``workload`` — trace fids materialized as real registered functions;
  * ``gateway``  — the front door: per-function routing, bounded
    per-tenant queues, token-bucket admission, SLO timeouts, worker
    threads; plus the platform ``Autoscaler``
    (``ArrivalRateEstimator`` -> ``AdaptivePoolPolicy`` ->
    ``resize_pool``) and the cluster ``ClusterBalancer`` (per-node
    commit spread + queue depth -> ``HydraCluster.rebalance()``
    mid-burst);
  * ``loadgen``  — open-loop arrival scheduling on the wall clock,
    optionally tenant-sharded across threads for high-compression
    replays (``ShardedLoadGenerator``);
  * ``recorder`` — live metrics -> ``SimResult``; the
    ``CalibrationProbe`` measures replay-window startup/warm/restore
    costs and RSS for the calibration round trip;
  * ``replay``   — ``replay_trace(trace, target, cfg)`` orchestration;
  * ``validate`` — sim-vs-real delta report + the enforced cold-start
    and p99 tolerance gates (CI ``gateway-smoke``), and the
    ``--round-trip`` mode that calibrates the sim from the live run
    itself and requires it to track live at least as tightly as the
    uncalibrated sim (CI ``roundtrip-smoke``).

Entry points: ``python -m repro.launch.serve --gateway --trace-file ...
--compress 60`` for a live replay, ``python -m repro.gateway.validate``
for the sim-vs-real diff (``--round-trip`` for the calibration loop).
"""
from repro.gateway.gateway import (Autoscaler, ClusterBalancer, Gateway,
                                   GatewayParams)
from repro.gateway.loadgen import (LoadGenerator, LoadResult,
                                   ShardedLoadGenerator, shard_trace)
from repro.gateway.recorder import CalibrationProbe, Recorder
from repro.gateway.replay import ReplayConfig, replay_trace
from repro.gateway.targets import (ClusterTarget, PlatformTarget,
                                   RuntimeTarget, TargetAdapter, wrap_target)
from repro.gateway.validate import (format_report, load_trace,
                                    round_trip_check, run_validation,
                                    sim_params_for_live)
from repro.gateway.workload import TraceWorkload, scaled_runtime_budget

__all__ = [
    "Gateway", "GatewayParams", "Autoscaler", "ClusterBalancer",
    "LoadGenerator", "LoadResult", "ShardedLoadGenerator", "shard_trace",
    "Recorder", "CalibrationProbe",
    "ReplayConfig", "replay_trace", "TargetAdapter",
    "RuntimeTarget", "PlatformTarget", "ClusterTarget", "wrap_target",
    "TraceWorkload", "scaled_runtime_budget", "run_validation",
    "round_trip_check", "format_report", "sim_params_for_live",
    "load_trace",
]
