"""AdamW with global-norm clipping (fp32 moments, pytree-native)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]   # step -> learning rate
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + \
                self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        new_state = {"m": m, "v": v, "step": step}
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics
