"""Gradient compression with error feedback (int8 quantized all-reduce).

Wraps any optimizer: gradients are quantized to int8 per-tensor-scale before
the (conceptual) cross-pod reduction, the dequantized values are applied,
and the quantization error is fed back into the next step's gradients —
bounding the bias (Karimireddy et al., error-feedback SGD).

On the wire this cuts the cross-pod all-reduce bytes 4x (fp32->int8); the
dry-run's collective term scales accordingly when enabled.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> tuple:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@dataclass(frozen=True)
class ErrorFeedbackCompression:
    """Optimizer wrapper: compress(grad + residual), apply, carry residual."""
    inner: object

    def init(self, params):
        return {
            "inner": self.inner.init(params),
            "residual": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(self, grads, state, params):
        def comp(g, r):
            corrected = g.astype(jnp.float32) + r
            q, scale = quantize(corrected)
            deq = dequantize(q, scale)
            return deq, corrected - deq

        pairs = jax.tree.map(comp, grads, state["residual"])
        deq = jax.tree.map(lambda t: t[0], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
        resid = jax.tree.map(lambda t: t[1], pairs,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_params, inner_state, metrics = self.inner.update(
            deq, state["inner"], params)
        metrics = dict(metrics)
        metrics["compression_bits"] = jnp.float32(8.0)
        return new_params, {"inner": inner_state, "residual": resid}, metrics
