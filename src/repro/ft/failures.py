"""Failure injection, straggler detection, elastic re-meshing.

On a real pod these hook the runtime's heartbeat bus; on the CPU host they
drive the SAME recovery code paths (restore + re-shard + resume) so the
logic is exercised end-to-end in tests and examples.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import jax
import numpy as np


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Deterministic or probabilistic step failures (node-loss simulation)."""
    fail_at_steps: tuple = ()
    fail_prob: float = 0.0
    seed: int = 0
    _fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")
        if self.fail_prob > 0:
            rng = np.random.default_rng((self.seed, step))
            if rng.random() < self.fail_prob and step not in self._fired:
                self._fired.add(step)
                raise InjectedFailure(f"random node failure at step {step}")


class HeartbeatMonitor:
    """Deadline-based straggler/failure detection.

    Workers call ``beat(worker_id)`` each step; ``stragglers(deadline_s)``
    returns workers silent for longer than the deadline. The trainer uses
    this to trigger checkpoint-restore-reshard (elastic) instead of hanging
    on a dead collective.
    """

    def __init__(self):
        self._last: dict = {}
        self._lock = threading.Lock()

    def beat(self, worker_id: str):
        with self._lock:
            self._last[worker_id] = time.monotonic()

    def stragglers(self, deadline_s: float) -> list:
        now = time.monotonic()
        with self._lock:
            return [w for w, t in self._last.items() if now - t > deadline_s]

    def workers(self) -> list:
        with self._lock:
            return list(self._last)


def elastic_remesh(tree, shardings):
    """Re-place a pytree onto new shardings (mesh grown or shrunk).

    Used after restore when the device pool changed: checkpoint leaves are
    host arrays; this scatters them onto the new mesh layout.
    """
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings)


@dataclass
class StepGuard:
    """Wraps the train loop body with failure detection + bounded retry."""
    monitor: HeartbeatMonitor
    injector: FailureInjector
    max_retries: int = 2

    def run(self, step: int, fn, *args, **kwargs):
        attempts = 0
        while True:
            try:
                self.injector.check(step)
                out = fn(*args, **kwargs)
                self.monitor.beat("worker0")
                return out
            except InjectedFailure:
                attempts += 1
                if attempts > self.max_retries:
                    raise
                # the caller restores from checkpoint on re-raise; here we
                # model a fast in-place retry (straggler mitigation)
                continue
