"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
           manifest.json        tree structure, shapes, dtypes, step
           <flat-key>.npy       one file per leaf (per-host shard in a real
                                multi-host run; full array on 1 host)
         <dir>/step_<N>.done    commit marker (atomic rename)

Restores re-shard onto WHATEVER mesh is current — the elastic-scaling path:
a checkpoint written on 256 chips restores onto 512 or 64 without format
changes (leaves are stored unsharded per-host; device placement is applied
at restore time from the caller's shardings).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import ml_dtypes  # noqa: F401  (bf16 <-> uint16 views)
import numpy as np

_EXOTIC = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
           "float8_e5m2": np.uint8}


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree, *, block: bool = True) -> str:
    """Atomic checkpoint write; returns the commit path."""
    flat, _ = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}_{os.getpid()}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "keys": {}}
    for key, leaf in flat.items():
        arr = np.asarray(leaf)
        fname = key.replace("/", "__") + ".npy"
        logical = str(arr.dtype)
        if logical in _EXOTIC:                 # numpy can't store bf16/f8
            np.save(os.path.join(tmp, fname), arr.view(_EXOTIC[logical]))
        else:
            np.save(os.path.join(tmp, fname), arr)
        manifest["keys"][key] = {"file": fname, "shape": list(arr.shape),
                                 "dtype": logical}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic commit
    with open(final + ".done", "w") as f:
        f.write(str(time.time()))
    return final


class AsyncCheckpointer:
    """Fire-and-forget saves on a writer thread (training never blocks on
    I/O); ``wait()`` joins outstanding writes before shutdown."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        os.makedirs(ckpt_dir, exist_ok=True)
        self._pending: list = []

    def save_async(self, step: int, tree):
        # snapshot to host memory synchronously (cheap), write async
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        t = threading.Thread(target=self._write, args=(step, host_tree),
                             daemon=True)
        t.start()
        self._pending.append(t)

    def _write(self, step, host_tree):
        save(self.ckpt_dir, step, host_tree)
        self._gc()

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)
            try:
                os.remove(os.path.join(self.ckpt_dir, f"step_{s}.done"))
            except OSError:
                pass

    def wait(self):
        for t in self._pending:
            t.join(timeout=30.0)
        self._pending.clear()


def list_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and name.endswith(".done"):
            out.append(int(name[len("step_"):-len(".done")]))
    return sorted(out)


def latest_step(ckpt_dir: str):
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, template, *, shardings=None):
    """Restore into the structure of ``template``; optionally re-shard onto
    the current mesh (elastic restore)."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = _flatten(template)
    leaves = []
    flat_s, _ = (_flatten(shardings) if shardings is not None
                 else ({}, None))
    for key, tmpl in flat_t.items():
        meta = manifest["keys"][key]
        arr = np.load(os.path.join(final, meta["file"]))
        if meta["dtype"] in _EXOTIC:
            arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        want = tuple(getattr(tmpl, "shape", arr.shape))
        assert tuple(arr.shape) == want, (key, arr.shape, want)
        sh = flat_s.get(key)
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    # rebuild in treedef order
    return jax.tree_util.tree_unflatten(treedef, leaves)
